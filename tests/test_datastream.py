"""repro.datastream: scheduler determinism, streamed-vs-in-memory
equivalence, kill-and-resume byte identity, reader round-trips, per-shard
feature streaming."""
import dataclasses
import hashlib
import os

import jax
import numpy as np
import pytest

from repro.core import rmat
from repro.core.structure import KroneckerFit
from repro.datastream import (ChunkScheduler, DatasetJob, FeatureSpec,
                              Manifest, ShardedGraphDataset, auto_k_pref,
                              pump_chunks)

FIT = KroneckerFit(a=0.45, b=0.22, c=0.2, d=0.13, n=12, m=12, E=60_000)


def _file_hashes(path):
    return {f: hashlib.md5(open(os.path.join(path, f), "rb").read())
            .hexdigest()
            for f in sorted(os.listdir(path)) if f.endswith(".npy")}


def _ks_degree_distance(deg_a, deg_b):
    """Kolmogorov–Smirnov distance between two degree distributions."""
    hi = int(max(deg_a.max(), deg_b.max())) + 1
    cdf_a = np.cumsum(np.bincount(deg_a, minlength=hi) / len(deg_a))
    cdf_b = np.cumsum(np.bincount(deg_b, minlength=hi) / len(deg_b))
    return float(np.abs(cdf_a - cdf_b).max())


# -- scheduler ---------------------------------------------------------------

def test_scheduler_partition_is_exact_and_deterministic():
    s1 = ChunkScheduler(FIT, shard_edges=8192, num_workers=3, seed=7)
    s2 = ChunkScheduler(FIT, shard_edges=8192, num_workers=3, seed=7)
    assert s1.shards == s2.shards
    assert s1.theta_digest == s2.theta_digest
    # shards cover every chunk exactly once, edges sum exactly to E
    covered = [i for sh in s1.shards for i in sh.chunk_indices]
    assert sorted(covered) == sorted(c.index for c in s1.chunks)
    assert s1.total_edges == FIT.E
    # worker queues partition the shard set
    queues = [s1.worker_queue(w) for w in range(3)]
    assert sum(len(q) for q in queues) == len(s1.shards)
    assert all(sh.worker == w for w, q in enumerate(queues) for sh in q)
    # resumable progress: pending() drops exactly the done ids
    done = [s.shard_id for s in s1.shards[:2]]
    assert [s.shard_id for s in s1.pending(done)] == \
        [s.shard_id for s in s1.shards[2:]]


def test_auto_k_pref_bounds_chunk_size():
    k = auto_k_pref(FIT, shard_edges=4096)
    sched = ChunkScheduler(FIT, shard_edges=4096, k_pref=k)
    pmax = max(FIT.a, FIT.b, FIT.c, FIT.d)
    assert FIT.E * pmax ** k <= 4096 or k == min(FIT.n, FIT.m) - 1
    # realized max chunk stays near the expected bound
    assert max(c.n_edges for c in sched.chunks) <= int(4096 * 1.5)


def test_chunk_keys_are_index_stable():
    s = ChunkScheduler(FIT, shard_edges=8192, seed=3)
    ck = s.chunks[5]
    np.testing.assert_array_equal(
        s.key_for(ck), rmat.chunk_key(jax.random.PRNGKey(3), ck.index))


# -- seeding contract (satellite fix) ---------------------------------------

def test_sample_chunk_requires_explicit_theta_noise():
    noisy = dataclasses.replace(FIT, noise=0.02)
    chunks = rmat.chunk_plan(noisy, 2)
    with pytest.raises(ValueError, match="derive"):
        rmat.sample_chunk(jax.random.PRNGKey(0), noisy, chunks[0], 2)
    th = rmat.derive_thetas(noisy, key=jax.random.PRNGKey(0))
    rmat.sample_chunk(jax.random.PRNGKey(0), noisy, chunks[0], 2, th)


def test_noise_differs_across_keys_but_is_key_deterministic():
    noisy = dataclasses.replace(FIT, noise=0.02)
    t0 = rmat.derive_thetas(noisy, key=jax.random.PRNGKey(0))
    t0b = rmat.derive_thetas(noisy, key=jax.random.PRNGKey(0))
    t1 = rmat.derive_thetas(noisy, key=jax.random.PRNGKey(1))
    np.testing.assert_array_equal(t0, t0b)
    assert not np.array_equal(t0, t1)


# -- streamed == in-memory ---------------------------------------------------

@pytest.mark.slow
def test_streamed_equals_oneshot_distribution(tmp_path):
    out = str(tmp_path / "ds")
    job = DatasetJob(FIT, out, shard_edges=8192, seed=0)
    job.run()
    ds = ShardedGraphDataset(out)
    g = ds.to_graph()
    assert g.n_edges == FIT.E                        # exact edge count
    s1, d1 = rmat.sample_graph(jax.random.PRNGKey(0), FIT)
    deg_stream = np.bincount(np.asarray(g.src), minlength=2 ** FIT.n)
    deg_one = np.bincount(np.asarray(s1), minlength=2 ** FIT.n)
    assert _ks_degree_distance(deg_stream, deg_one) < 0.02
    deg_stream_in = np.bincount(np.asarray(g.dst), minlength=2 ** FIT.m)
    deg_one_in = np.bincount(np.asarray(d1), minlength=2 ** FIT.m)
    assert _ks_degree_distance(deg_stream_in, deg_one_in) < 0.02


def test_streamed_matches_chunked_sampler_exactly(tmp_path):
    out = str(tmp_path / "ds")
    job = DatasetJob(FIT, out, shard_edges=8192, seed=0)
    job.run()
    g = ShardedGraphDataset(out).to_graph()
    s, d = rmat.sample_graph_chunked(jax.random.PRNGKey(0), FIT,
                                     k_pref=job.k_pref)
    # same chunk keys + same θ ⇒ identical multisets of edges
    np.testing.assert_array_equal(np.sort(np.asarray(g.src)),
                                  np.sort(np.asarray(s)))
    np.testing.assert_array_equal(np.sort(np.asarray(g.dst)),
                                  np.sort(np.asarray(d)))


def test_serial_and_double_buffered_are_identical(tmp_path):
    a, b = str(tmp_path / "a"), str(tmp_path / "b")
    DatasetJob(FIT, a, shard_edges=8192, double_buffered=True).run()
    DatasetJob(FIT, b, shard_edges=8192, double_buffered=False).run()
    assert _file_hashes(a) == _file_hashes(b)


# -- kill and resume ---------------------------------------------------------

def test_kill_and_resume_is_byte_identical(tmp_path):
    full, part = str(tmp_path / "full"), str(tmp_path / "part")
    DatasetJob(FIT, full, shard_edges=8192, seed=0).run()
    # simulate preemption after 3 shards
    DatasetJob(FIT, part, shard_edges=8192, seed=0).run(max_shards=3)
    m = Manifest.load(part)
    assert len(m.done_ids()) == 3 and not m.is_complete()
    with pytest.raises(RuntimeError, match="incomplete"):
        ShardedGraphDataset(part)
    before = _file_hashes(part)
    m2 = DatasetJob(FIT, part, shard_edges=8192, seed=0).resume()
    assert m2.is_complete()
    after = _file_hashes(part)
    # finished shards untouched, and the whole dataset matches an
    # uninterrupted run byte for byte
    assert all(after[f] == h for f, h in before.items())
    assert after == _file_hashes(full)
    assert ShardedGraphDataset(part).verify(deep=True) == []


def test_resume_regenerates_corrupted_shard(tmp_path):
    out = str(tmp_path / "ds")
    DatasetJob(FIT, out, shard_edges=8192, seed=0).run(max_shards=2)
    victim = Manifest.load(out).shards[0].files["src"]
    os.remove(os.path.join(out, victim))
    m = DatasetJob(FIT, out, shard_edges=8192, seed=0).resume()
    assert m.is_complete()
    assert ShardedGraphDataset(out).verify(deep=True) == []


def test_resume_refuses_mismatched_config(tmp_path, rng):
    out = str(tmp_path / "ds")
    DatasetJob(FIT, out, shard_edges=8192, seed=0).run(max_shards=1)
    with pytest.raises(ValueError, match="different"):
        DatasetJob(FIT, out, shard_edges=8192, seed=1).resume()
    # a resumed job must produce the same columns: features on/off mismatch
    spec, _ = _fitted_feature_spec(rng)
    with pytest.raises(ValueError, match="features"):
        DatasetJob(FIT, out, shard_edges=8192, seed=0,
                   features=spec).resume()
    # a different feature jit batch is a different feature stream for
    # engine-batched generators — the recorded batch must refuse to
    # resume too (numpy-only specs like KDE skip the pin entirely)
    from repro.core.features import GANFeatureGenerator
    r = np.random.default_rng(0)
    cont = r.normal(size=(200, 1)).astype(np.float32)
    cat = r.integers(0, 2, size=(200, 1)).astype(np.int32)
    from repro.tabular.schema import infer_schema
    gan = GANFeatureGenerator(infer_schema(cont, cat)).fit(cont, cat,
                                                           steps=3)
    out_f = out + "_feat"
    DatasetJob(FIT, out_f, shard_edges=8192, seed=0,
               features=FeatureSpec(gan)).run(max_shards=1)
    assert Manifest.load(out_f).features["batch"] == 8192
    with pytest.raises(ValueError, match="features"):
        DatasetJob(FIT, out_f, shard_edges=8192, seed=0,
                   features=FeatureSpec(gan, batch=4096)).resume()
    # device_steps resumption depends on the mesh size
    m = Manifest.load(out)
    m.mode, m.n_dev = "device_steps", 4
    m.save(out)
    with pytest.raises(ValueError, match="n_dev"):
        DatasetJob(FIT, out, shard_edges=8192, seed=0,
                   mode="device_steps").resume()
    with pytest.raises(FileExistsError):
        DatasetJob(FIT, out, shard_edges=8192, seed=0).run()  # no resume


def test_journal_replay_recovers_uncompacted_progress(tmp_path):
    """A crash before manifest compaction loses nothing: per-shard
    completions live in progress.jsonl and Manifest.load replays them."""
    from repro.datastream.writer import JOURNAL_NAME, ShardWriter
    out = str(tmp_path / "ds")
    job = DatasetJob(FIT, out, shard_edges=8192, seed=0)
    manifest = job.plan()
    writer = ShardWriter(out, manifest, checkpoint_every=10_000)
    rec = manifest.shards[0]
    writer.write_shard(0, job.source.generate(rec))
    # no compaction yet: on-disk manifest.json is stale, journal is not
    import json as _json
    raw = _json.load(open(os.path.join(out, "manifest.json")))
    assert all(s["status"] == "pending" for s in raw["shards"])
    assert os.path.getsize(os.path.join(out, JOURNAL_NAME)) > 0
    assert Manifest.load(out).done_ids() == [0]       # replayed
    before = _file_hashes(out)
    m2 = DatasetJob(FIT, out, shard_edges=8192, seed=0).resume()
    assert m2.is_complete()
    after = _file_hashes(out)
    assert all(after[f] == h for f, h in before.items())
    # resume compacted: journal truncated, manifest current
    assert os.path.getsize(os.path.join(out, JOURNAL_NAME)) == 0
    assert ShardedGraphDataset(out).verify(deep=True) == []


# -- reader ------------------------------------------------------------------

def test_reader_batches_and_verify(tmp_path):
    out = str(tmp_path / "ds")
    DatasetJob(FIT, out, shard_edges=8192, seed=0).run()
    ds = ShardedGraphDataset(out)
    assert ds.total_edges == FIT.E and len(ds) >= 2
    sizes = []
    seen = 0
    for src, dst, cont, cat in ds.batches(10_000):
        assert len(src) == len(dst)
        assert cont is None and cat is None
        sizes.append(len(src))
        seen += len(src)
    assert seen == FIT.E
    assert all(s == 10_000 for s in sizes[:-1])
    assert ds.verify(deep=True) == []


def test_device_steps_multidevice(tmp_path):
    """device_steps on a >1-device mesh: per-device prefixes cover the id
    space, dst levels keep full θ rows (noise on would misalign otherwise),
    and the dataset verifies."""
    import subprocess
    import sys
    script = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np
from repro.core.structure import KroneckerFit
from repro.datastream import DatasetJob, ShardedGraphDataset
fit = KroneckerFit(a=0.45, b=0.22, c=0.2, d=0.13, n=10, m=10, E=20000,
                   noise=0.03)
job = DatasetJob(fit, {str(tmp_path / 'ds')!r}, shard_edges=8192, seed=0,
                 mode="device_steps")
job.run()
ds = ShardedGraphDataset({str(tmp_path / 'ds')!r})
assert ds.manifest.n_dev == 4, ds.manifest.n_dev
assert ds.verify(deep=True) == []
g = ds.to_graph()
assert g.n_edges == fit.E
src = np.asarray(g.src)
assert src.max() < 2 ** fit.n
assert sorted(np.unique(src >> (fit.n - 2)).tolist()) == [0, 1, 2, 3]
print("multidevice ok")
"""
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env=env, timeout=300,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"


def test_device_steps_mode(tmp_path):
    out = str(tmp_path / "ds")
    job = DatasetJob(FIT, out, shard_edges=16_384, seed=0,
                     mode="device_steps")
    job.run()
    ds = ShardedGraphDataset(out)
    g = ds.to_graph()
    assert g.n_edges == FIT.E
    assert ds.verify(deep=True) == []
    assert np.asarray(g.src).max() < 2 ** FIT.n


# -- per-shard features ------------------------------------------------------

def _fitted_feature_spec(rng):
    from repro.core.aligner import RandomAligner
    from repro.core.features import KDEFeatureGenerator
    from repro.tabular.schema import infer_schema
    cont = rng.normal(size=(500, 2)).astype(np.float32)
    cat = rng.integers(0, 3, size=(500, 1)).astype(np.int32)
    schema = infer_schema(cont, cat)
    gen = KDEFeatureGenerator(schema).fit(cont, cat)
    return FeatureSpec(gen, RandomAligner(schema)), schema


def test_feature_streaming_bounded_per_shard(tmp_path, rng):
    spec, schema = _fitted_feature_spec(rng)
    out = str(tmp_path / "ds")
    job = DatasetJob(FIT, out, shard_edges=8192, seed=0, features=spec)
    job.run()
    ds = ShardedGraphDataset(out)
    assert ds.has_features
    # pure-numpy spec (KDE + RandomAligner): no engine batch/device pin,
    # so these datasets stay resumable across hosts
    assert ds.manifest.features == {"n_cont": 2, "cat_cards": [3]}
    total = 0
    for blk in ds:
        assert blk.cont.shape == (blk.n_edges, 2)
        assert blk.cat.shape == (blk.n_edges, 1)
        assert blk.cat.max() < 3
        total += blk.n_edges
    assert total == FIT.E
    # feature draw is a pure function of (seed, shard_id): resume after
    # deleting a shard reproduces identical features
    files = Manifest.load(out).shards[1].files
    before = _file_hashes(out)
    os.remove(os.path.join(out, files["cont"]))
    DatasetJob(FIT, out, shard_edges=8192, seed=0,
               features=spec).resume()
    assert _file_hashes(out) == before


def test_pipeline_generate_streamed(tmp_path, rng):
    from repro.core.pipeline import SyntheticGraphPipeline
    from repro.graph.ops import Graph
    src = rng.integers(0, 256, 4000).astype(np.int32)
    dst = rng.integers(0, 256, 4000).astype(np.int32)
    g = Graph(src, dst, 256, 256)
    cont = rng.normal(size=(4000, 2)).astype(np.float32)
    cat = rng.integers(0, 3, size=(4000, 1)).astype(np.int32)
    pipe = SyntheticGraphPipeline(features="kde", aligner="random")
    pipe.fit(g, cont, cat)
    ds = pipe.generate_streamed(str(tmp_path / "ds"), seed=0,
                                shard_edges=2048)
    assert ds.total_edges == pipe.struct.E
    assert ds.has_features
    assert ds.verify(deep=True) == []
    # per-stage timing split: feature/align wall-time is no longer lumped
    # into gen_struct_s
    t = pipe.timings
    assert t.gen_struct_s > 0 and t.gen_feat_s > 0 and t.gen_align_s > 0
    # structure-only stream leaves the feature/align stages at zero
    pipe2 = SyntheticGraphPipeline(features="kde", aligner="random")
    pipe2.fit(g, cont, cat)
    pipe2.generate_streamed(str(tmp_path / "ds2"), seed=0, shard_edges=2048,
                            include_features=False)
    assert pipe2.timings.gen_struct_s > 0
    assert pipe2.timings.gen_feat_s == 0.0
    assert pipe2.timings.gen_align_s == 0.0


# -- pipelined executor ------------------------------------------------------

def _manifest_sans_executor(path):
    import json as _json
    with open(os.path.join(path, "manifest.json")) as f:
        d = _json.load(f)
    d.pop("executor", None)
    return d


def test_pipelined_golden_equals_serial_chunks_with_features(tmp_path, rng):
    """Golden-seed byte identity: the pipelined executor (overlapped
    struct/feature/IO stages, parallel host workers) must produce the
    exact bytes of the serial loop — shards AND manifest (modulo the
    executor provenance knobs, which are recorded but byte-transparent)."""
    spec, _ = _fitted_feature_spec(rng)
    a, b = str(tmp_path / "serial"), str(tmp_path / "pipe")
    DatasetJob(FIT, a, shard_edges=8192, seed=0, features=spec,
               pipeline_depth=0).run()
    DatasetJob(FIT, b, shard_edges=8192, seed=0, features=spec,
               pipeline_depth=3, host_workers=2).run()
    assert _file_hashes(a) == _file_hashes(b)
    assert _manifest_sans_executor(a) == _manifest_sans_executor(b)
    assert ShardedGraphDataset(b).verify(deep=True) == []


def test_pipelined_golden_equals_serial_device_steps(tmp_path):
    a, b = str(tmp_path / "serial"), str(tmp_path / "pipe")
    DatasetJob(FIT, a, shard_edges=16_384, seed=0, mode="device_steps",
               pipeline_depth=0).run()
    DatasetJob(FIT, b, shard_edges=16_384, seed=0, mode="device_steps",
               pipeline_depth=2).run()
    assert _file_hashes(a) == _file_hashes(b)
    assert _manifest_sans_executor(a) == _manifest_sans_executor(b)


# -- fused device-resident generation ----------------------------------------

#: small fit for the fused golden tests: the fused program compiles once
#: per distinct shard chunk-shape, so keep the shard count low.  E is NOT
#: a multiple of shard_edges ⇒ the last shard is ragged.
FIT_FUSED = KroneckerFit(a=0.45, b=0.22, c=0.2, d=0.13, n=10, m=10,
                         E=14_000)


def _gan_gbdt_spec(rng, batch=None):
    """A fitted GAN generator + GBDT aligner: the fully fusable feature
    stage (``GANFeatureGenerator.block_draw`` is traceable, so ``fused``
    runs R-MAT descent AND Gumbel-max feature decode in one jitted
    program per block; the GBDT alignment stays on the host stage)."""
    from repro.core.aligner import AlignerConfig, GBDTAligner
    from repro.core.features import GANConfig, GANFeatureGenerator
    from repro.core.gbdt import GBDTConfig
    from repro.graph.ops import Graph
    from repro.tabular.schema import infer_schema
    cont = rng.normal(size=(400, 2)).astype(np.float32)
    cat = rng.integers(0, 3, size=(400, 1)).astype(np.int32)
    schema = infer_schema(cont, cat)
    gen = GANFeatureGenerator(schema, GANConfig(batch=64)).fit(
        cont, cat, steps=5, seed=0)
    g = Graph(rng.integers(0, 64, 400).astype(np.int32),
              rng.integers(0, 64, 400).astype(np.int32), 64, 64)
    al = GBDTAligner(schema, AlignerConfig(
        gbdt=GBDTConfig(n_rounds=4, max_depth=3)), kind="edge").fit(
            g, cont, cat)
    return FeatureSpec(gen, al, batch=batch)


def test_fused_golden_equals_staged_chunks_with_features(tmp_path, rng):
    """Tentpole golden-seed byte identity: fused device-resident
    generation (one jitted program per block running struct descent +
    feature decode) must produce the exact bytes of the staged path —
    shards AND manifest, modulo the provenance-only executor knobs."""
    spec = _gan_gbdt_spec(rng, batch=1024)
    a, b = str(tmp_path / "staged"), str(tmp_path / "fused")
    DatasetJob(FIT_FUSED, a, shard_edges=4096, seed=0, features=spec).run()
    DatasetJob(FIT_FUSED, b, shard_edges=4096, seed=0, features=spec,
               fused=True).run()
    assert _file_hashes(a) == _file_hashes(b)
    assert _manifest_sans_executor(a) == _manifest_sans_executor(b)
    assert ShardedGraphDataset(b).verify(deep=True) == []


def test_fused_golden_equals_staged_device_steps(tmp_path, rng):
    spec = _gan_gbdt_spec(rng, batch=1024)
    a, b = str(tmp_path / "staged"), str(tmp_path / "fused")
    DatasetJob(FIT_FUSED, a, shard_edges=4096, seed=0,
               mode="device_steps", features=spec).run()
    DatasetJob(FIT_FUSED, b, shard_edges=4096, seed=0,
               mode="device_steps", features=spec, fused=True).run()
    assert _file_hashes(a) == _file_hashes(b)
    assert _manifest_sans_executor(a) == _manifest_sans_executor(b)
    assert ShardedGraphDataset(b).verify(deep=True) == []


def test_fused_padded_tail_blocks(tmp_path, rng):
    """No shard size divides the feature batch: every fused block run
    ends in a padded tail (4096 % 1000, ragged final shard % 1000), and
    the trimmed rows must still match the staged path byte-for-byte."""
    spec = _gan_gbdt_spec(rng, batch=1000)
    a, b = str(tmp_path / "staged"), str(tmp_path / "fused")
    DatasetJob(FIT_FUSED, a, shard_edges=4096, seed=0, features=spec).run()
    DatasetJob(FIT_FUSED, b, shard_edges=4096, seed=0, features=spec,
               fused=True).run()
    assert _file_hashes(a) == _file_hashes(b)


def test_pipelined_overlap_reported(tmp_path):
    job = DatasetJob(FIT, str(tmp_path / "ds"), shard_edges=8192,
                     pipeline_depth=2)
    job.run()
    t = job.timings
    assert t["wall_s"] > 0 and t["gen_struct_s"] > 0 and t["write_s"] > 0
    # busy time is accounted per stage; overlap = busy/wall is >= ~1 when
    # the pipeline engages (equality would mean fully serial behaviour)
    assert t["overlap"] == pytest.approx(
        (t["gen_struct_s"] + t["gen_feat_s"] + t["gen_align_s"]
         + t["write_s"]) / t["wall_s"])


class _FlakyGen:
    """Wraps a fitted generator; raises on the ``fail_at``-th draw."""

    def __init__(self, inner, fail_at):
        self.inner = inner
        self.schema = inner.schema
        self.fail_at = fail_at
        self.calls = 0
        self._lock = __import__("threading").Lock()

    def sample(self, rng, n):
        with self._lock:
            self.calls += 1
            boom = self.calls == self.fail_at
        if boom:
            raise RuntimeError("injected feature-stage failure")
        return self.inner.sample(rng, n)


def test_pipelined_resume_under_preemption_with_features(tmp_path, rng):
    """Kill mid-pipeline with shards queued but uncommitted: the journal
    must stay a clean prefix (no duplicate/missing records), and resume
    must complete byte-identical to an uninterrupted run."""
    spec, schema = _fitted_feature_spec(rng)
    full, part = str(tmp_path / "full"), str(tmp_path / "part")
    DatasetJob(FIT, full, shard_edges=8192, seed=0, features=spec,
               pipeline_depth=0).run()
    n_shards = len(Manifest.load(full).shards)
    assert n_shards >= 4
    flaky = FeatureSpec(_FlakyGen(spec.generator, fail_at=4), spec.aligner)
    with pytest.raises(RuntimeError, match="injected"):
        DatasetJob(FIT, part, shard_edges=8192, seed=0, features=flaky,
                   pipeline_depth=2, host_workers=2).run()
    m = Manifest.load(part)
    done = m.done_ids()
    # in-order commits ⇒ the done set is a contiguous prefix, each shard
    # recorded exactly once, and nothing past the failure was journaled
    assert done == list(range(len(done)))
    assert 0 < len(done) < n_shards
    before = _file_hashes(part)
    m2 = DatasetJob(FIT, part, shard_edges=8192, seed=0, features=spec,
                    pipeline_depth=2, host_workers=2).resume()
    assert m2.is_complete()
    assert sorted(m2.done_ids()) == list(range(n_shards))
    after = _file_hashes(part)
    assert all(after[f] == h for f, h in before.items())  # prefix untouched
    assert after == _file_hashes(full)                    # byte-identical
    assert ShardedGraphDataset(part).verify(deep=True) == []


def test_device_steps_worker_striping(tmp_path):
    """device_steps shards stripe across worker queues; formerly any
    worker id != 0 silently skipped every shard."""
    out = str(tmp_path / "ds")
    job = DatasetJob(FIT, out, shard_edges=8192, seed=0,
                     mode="device_steps", num_workers=2)
    job.run(worker=0)
    m = Manifest.load(out)
    assert 0 < len(m.done_ids()) < len(m.shards)
    job2 = DatasetJob(FIT, out, shard_edges=8192, seed=0,
                      mode="device_steps", num_workers=2)
    job2.run(resume=True, worker=1)
    assert Manifest.load(out).is_complete()
    with pytest.raises(ValueError, match="worker"):
        job2.run(resume=True, worker=5)


def test_resume_restripes_across_different_worker_count(tmp_path):
    """Worker queues follow the *running* job's num_workers: a dataset
    planned single-process can be finished by N resuming processes."""
    out = str(tmp_path / "ds")
    DatasetJob(FIT, out, shard_edges=8192, seed=0).run(max_shards=2)
    jobs = [DatasetJob(FIT, out, shard_edges=8192, seed=0, num_workers=2)
            for _ in range(2)]
    m0 = jobs[0].run(resume=True, worker=0)
    assert not m0.is_complete()          # worker 0's queue only
    jobs[1].run(resume=True, worker=1)
    assert Manifest.load(out).is_complete()
    assert ShardedGraphDataset(out).verify(deep=True) == []


# -- streamed deep verify ----------------------------------------------------

def test_crc32_stream_matches_oneshot():
    from repro.datastream.writer import _crc32, _crc32_stream
    arr = np.arange(10_007, dtype=np.int64)
    assert _crc32_stream(arr, block_rows=64) == _crc32(arr)
    assert _crc32_stream(arr, block_rows=1 << 30) == _crc32(arr)
    assert _crc32_stream(arr[:0], block_rows=64) == _crc32(arr[:0])


@pytest.mark.parametrize("arr", [
    np.arange(10_007, dtype=np.int64),
    np.arange(33, dtype=np.int32).reshape(11, 3) * 7,      # 2-D, small
    np.zeros((0,), np.float32),                            # empty
    np.random.default_rng(0).normal(size=(5000, 4)).astype(np.float32),
], ids=["int64-1d", "int32-2d", "empty", "f32-2d"])
def test_fused_save_crc_matches_legacy_bytes_and_digest(tmp_path, arr):
    """The fused single-pass save+crc (which replaced the np.save +
    .tobytes() staging copy + crc triple pass) must stay byte-identical
    on disk and digest-identical to the legacy path, across dtypes,
    shapes, empties, and block boundaries."""
    from repro.datastream.writer import (_atomic_save_npy,
                                         _atomic_save_npy_crc, _crc32)
    legacy, fused = str(tmp_path / "legacy.npy"), str(tmp_path / "f.npy")
    _atomic_save_npy(legacy, arr)
    # tiny block size forces the multi-block chaining path
    crc = _atomic_save_npy_crc(fused, arr, block_bytes=64)
    assert open(fused, "rb").read() == open(legacy, "rb").read()
    assert crc == _crc32(arr)
    np.testing.assert_array_equal(np.load(fused), arr)
    assert not os.path.exists(fused + ".tmp")              # atomic rename


def test_deep_verify_streams_blocks_and_catches_corruption(
        tmp_path, monkeypatch):
    from repro.datastream import writer as writer_mod
    out = str(tmp_path / "ds")
    DatasetJob(FIT, out, shard_edges=8192, seed=0).run()
    # force many blocks per shard so the streamed path really chains
    monkeypatch.setattr(writer_mod, "CRC_BLOCK_ROWS", 1000)
    assert ShardedGraphDataset(out).verify(deep=True) == []
    victim = Manifest.load(out).shards[0].files["src"]
    path = os.path.join(out, victim)
    with open(path, "r+b") as f:
        f.seek(-1, os.SEEK_END)
        last = f.read(1)[0]
        f.seek(-1, os.SEEK_END)
        f.write(bytes([last ^ 0xFF]))
    # shallow verify can't see a bit flip; streamed deep verify must
    ds = ShardedGraphDataset(out)
    assert ds.verify(deep=False) == []
    assert any("shard 0" in p for p in ds.verify(deep=True))


# -- pump --------------------------------------------------------------------

def test_pump_chunks_order_and_completeness():
    items = list(range(7))
    for dbl in (True, False):
        flushed = []
        n = pump_chunks(items, dispatch=lambda i: np.full(3, i),
                        flush=lambda i, host: flushed.append((i, host.sum())),
                        double_buffered=dbl)
        assert n == 7
        assert [i for i, _ in flushed] == items
        assert [s for _, s in flushed] == [3 * i for i in items]
