"""Dry-run machinery: cost-probe accuracy vs fully-unrolled ground truth,
cell lowering on a small mesh, chunked-generation cell (all in subprocesses
with forced multi-device CPU)."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest


def _run(body: str, devices: int = 8, timeout: int = 900):
    script = ("import os\n"
              f"os.environ['XLA_FLAGS']='--xla_force_host_platform_device_count={devices}'\n"
              + textwrap.dedent(body))
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env=env, timeout=timeout,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-4000:]}"
    return r.stdout


@pytest.mark.slow
def test_probe_extrapolation_matches_unrolled_truth():
    """probe(L=1,2)-extrapolated flops == fully-unrolled L=6 flops (±3%)."""
    _run("""
    import jax, dataclasses
    from repro.configs import get_config
    from repro.configs.base import ShapeSpec
    from repro.launch.costs import probe_costs, _lower_costs, _probe_cfg
    from repro.launch.mesh import make_local_mesh
    mesh = make_local_mesh(2, 4)
    cfg = get_config("tinyllama-1.1b").smoke().replace(n_layers=6)
    shape = ShapeSpec("t", 64, 8, "train")
    probe = probe_costs(cfg, shape, mesh)
    truth = _lower_costs(_probe_cfg(cfg), shape, mesh)
    rel = abs(probe.flops - truth["flops"]) / truth["flops"]
    print("flops rel err:", rel)
    assert rel < 0.03, (probe.flops, truth["flops"])
    relb = abs(probe.bytes - truth["bytes"]) / truth["bytes"]
    print("bytes rel err:", relb)
    # bf16-on-CPU convert chains add a superlinear bytes term the probe's
    # L∈{1,2} fit underestimates (absent on TPU; see launch/costs.py)
    assert relb < 0.30, (probe.bytes, truth["bytes"])
    # collectives: counts must match exactly
    assert probe.coll_counts == truth["coll"]["counts"], (
        probe.coll_counts, truth["coll"]["counts"])
    """)


@pytest.mark.slow
def test_chunk_extrapolated_probe_matches_direct():
    """The nc∈{2,4,8} quadratic fit reproduces a directly-probed nc=16 cell."""
    _run("""
    import jax
    from repro.configs import get_config
    from repro.configs.base import ShapeSpec
    from repro.launch import costs as C
    from repro.launch.mesh import make_local_mesh
    mesh = make_local_mesh(2, 4)
    cfg = get_config("rwkv6-7b").smoke()   # chunk=16 in smoke
    S = 16 * cfg.rwkv.chunk
    shape = ShapeSpec("t", S, 8, "prefill")
    direct = C._probe_costs_depth(cfg, shape, mesh)
    fitted = C._probe_costs_chunk_extrapolated(cfg, shape, mesh, None,
                                               (cfg.rwkv.chunk, 16))
    rel = abs(fitted.flops - direct.flops) / direct.flops
    print("chunk-fit flops rel err:", rel)
    assert rel < 0.05, (fitted.flops, direct.flops)
    """, timeout=1200)


@pytest.mark.parametrize("arch", ["llama3-8b", "qwen3-moe-30b-a3b"])
def test_cell_lowers_on_small_mesh(arch):
    """build_cell (smoke config) lowers+compiles on a 2x4 mesh with the
    same sharding rules as production."""
    _run(f"""
    import jax
    from repro.configs import get_config
    from repro.configs.base import ShapeSpec
    from repro.launch.mesh import make_local_mesh
    from repro.training.steps import build_cell
    mesh = make_local_mesh(2, 4)
    cfg = get_config({arch!r}).smoke()
    for shape in (ShapeSpec("t", 64, 8, "train"),
                  ShapeSpec("p", 64, 8, "prefill"),
                  ShapeSpec("d", 64, 8, "decode")):
        cell = build_cell(cfg, shape, mesh)
        with mesh:
            c = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                        out_shardings=cell.out_shardings).lower(
                *cell.args).compile()
        from repro.utils import cost_analysis_compat
        assert cost_analysis_compat(c)["flops"] > 0
        print(shape.kind, "ok")
    """)


def test_graphgen_cell_zero_collectives():
    """Chunked generation on the mesh: compiles and has NO collectives."""
    _run("""
    import jax
    from repro.core.distributed_gen import build_generation_cell
    from repro.launch.costs import parse_collectives
    from repro.launch.mesh import make_local_mesh
    mesh = make_local_mesh(2, 4)
    cell = build_generation_cell(mesh, "100b", edges_per_device=1 << 12)
    with mesh:
        c = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                    out_shardings=cell.out_shardings).lower(*cell.args).compile()
    colls = parse_collectives(c.as_text(), 4)
    print("collectives:", colls["counts"])
    assert colls["payload_bytes"] == 0, colls
    """)


def test_distributed_generation_executes():
    """Actually run a tiny distributed generation step and check prefix
    disjointness across devices."""
    _run("""
    import jax, numpy as np, jax.numpy as jnp
    from repro.core.distributed_gen import device_generate
    from repro.launch.mesh import make_local_mesh
    mesh = make_local_mesh(2, 2)
    n = m = 8
    thetas = jnp.asarray(np.tile([0.45, 0.22, 0.2, 0.13], (8, 1)), jnp.float32)
    seeds = jnp.arange(4, dtype=jnp.int32)
    with mesh:
        src, dst = device_generate(thetas, seeds, n, m, 1024, mesh)
    src = np.asarray(src).reshape(4, -1)
    prefixes = np.unique(src >> n)
    assert sorted(prefixes.tolist()) == [0, 1, 2, 3], prefixes
    print("prefixes ok")
    """)
