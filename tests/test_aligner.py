"""GBDT predictor + aligner: regression quality, JAX/numpy prediction
equality, and the structure↔feature coupling the aligner must preserve."""
import numpy as np
import pytest

from repro.core.aligner import AlignerConfig, GBDTAligner, RandomAligner
from repro.core.gbdt import GBDTClassifier, GBDTConfig, GBDTRegressor
from repro.data.reference import tabformer_like
from repro.graph.ops import Graph, out_degrees
from repro.tabular.schema import infer_schema

FAST = GBDTConfig(n_rounds=30, max_depth=4, lr=0.2, alpha=0.1)


def test_gbdt_fits_nonlinear_function(rng):
    X = rng.uniform(-2, 2, (2000, 3)).astype(np.float32)
    y = np.sin(X[:, 0] * 2) + 0.5 * X[:, 1] ** 2 + 0.1 * rng.normal(size=2000)
    m = GBDTRegressor(FAST).fit(X, y)
    pred = m.predict_np(X)
    ss_res = ((pred - y) ** 2).sum()
    ss_tot = ((y - y.mean()) ** 2).sum()
    r2 = 1 - ss_res / ss_tot
    assert r2 > 0.7, r2


def test_gbdt_jax_predict_matches_numpy(rng):
    X = rng.normal(0, 1, (500, 4)).astype(np.float32)
    y = X[:, 0] * 2 - X[:, 2] + rng.normal(0, 0.1, 500)
    m = GBDTRegressor(GBDTConfig(n_rounds=10, max_depth=3)).fit(X, y)
    np.testing.assert_allclose(np.asarray(m.predict(X)), m.predict_np(X),
                               rtol=1e-4, atol=1e-4)


def test_gbdt_classifier_separable(rng):
    X = rng.normal(0, 1, (1000, 2)).astype(np.float32)
    y = (X[:, 0] + X[:, 1] > 0).astype(np.int32)
    m = GBDTClassifier(2, FAST).fit(X, y)
    acc = (m.predict_np(X) == y).mean()
    assert acc > 0.9, acc


def test_gbdt_alpha_regularizes(rng):
    """Higher L1 alpha shrinks leaf magnitudes."""
    X = rng.normal(0, 1, (500, 2)).astype(np.float32)
    y = X[:, 0] + rng.normal(0, 0.05, 500)
    small = GBDTRegressor(GBDTConfig(n_rounds=5, alpha=0.0)).fit(X, y)
    big = GBDTRegressor(GBDTConfig(n_rounds=5, alpha=50.0)).fit(X, y)
    mag = lambda m: np.mean([np.abs(t.leaf).max() for t in m.trees])
    assert mag(big) < mag(small)


def _planted():
    """Graph whose first edge feature is a deterministic function of the
    src degree — the exact coupling the aligner must reconstruct."""
    g, cont, cat = tabformer_like(seed=0, n_src=512, n_dst=64, n_edges=4000)
    deg = np.asarray(out_degrees(g)).astype(np.float64)
    coupled = np.log1p(deg[np.asarray(g.src)]) + 0.01 * np.random.default_rng(
        0).normal(size=g.n_edges)
    cont = cont.copy()
    cont[:, 0] = coupled
    return g, cont.astype(np.float32), cat


def test_aligner_beats_random_on_planted_coupling():
    g, cont, cat = _planted()
    schema = infer_schema(cont, cat)
    cfg = AlignerConfig(gbdt=FAST)
    rng = np.random.default_rng(0)

    rows_c, rows_k = cont.copy(), cat.copy()   # use real rows as "generated"
    perm = rng.permutation(len(rows_c))
    rows_c, rows_k = rows_c[perm], rows_k[perm]

    deg_edge = np.asarray(out_degrees(g))[np.asarray(g.src)]

    al = GBDTAligner(schema, cfg, kind="edge").fit(g, cont, cat)
    a_c, _ = al.align(g, rows_c, rows_k, np.random.default_rng(1))
    r_c, _ = RandomAligner(schema).align(g, rows_c, rows_k,
                                         np.random.default_rng(1))
    corr_aligned = np.corrcoef(a_c[:, 0], np.log1p(deg_edge[: len(a_c)]))[0, 1]
    corr_random = np.corrcoef(r_c[:, 0], np.log1p(deg_edge[: len(r_c)]))[0, 1]
    assert corr_aligned > 0.8, corr_aligned
    assert corr_aligned > corr_random + 0.5, (corr_aligned, corr_random)


def test_aligner_align_preserves_rows():
    """Alignment is a permutation — the multiset of rows is unchanged."""
    g, cont, cat = _planted()
    schema = infer_schema(cont, cat)
    al = GBDTAligner(schema, AlignerConfig(gbdt=FAST), kind="edge").fit(
        g, cont, cat)
    a_c, a_k = al.align(g, cont, cat, np.random.default_rng(0))
    np.testing.assert_allclose(np.sort(a_c[:, 0]), np.sort(cont[:, 0]))
    assert sorted(a_k[:, 0].tolist()) == sorted(cat[: len(a_k), 0].tolist())


def test_config_defaults_not_shared():
    """Regression: ``cfg=GBDTConfig()`` / ``cfg=AlignerConfig()`` defaults
    used to be evaluated once at def time and aliased across instances."""
    from repro.tabular.schema import TableSchema
    r1, r2 = GBDTRegressor(), GBDTRegressor()
    assert r1.cfg is not r2.cfg
    r1.cfg.n_rounds = 1
    assert r2.cfg.n_rounds != 1
    c1, c2 = GBDTClassifier(2), GBDTClassifier(2)
    assert c1.cfg is not c2.cfg
    s = TableSchema(n_cont=1, cat_cards=())
    a1, a2 = GBDTAligner(s), GBDTAligner(s)
    assert a1.cfg is not a2.cfg and a1.cfg.gbdt is not a2.cfg.gbdt
    a1.cfg.max_cat_classes = 3
    assert a2.cfg.max_cat_classes != 3


def test_classifier_packed_predict_matches_np(rng):
    """The multi-output packed scan scores all classes in one call and
    matches the per-class numpy reference exactly (argmax) / closely
    (probabilities)."""
    X = rng.normal(0, 1, (600, 3)).astype(np.float32)
    y = ((X[:, 0] + X[:, 1] > 0).astype(np.int32)
         + (X[:, 2] > 0.5).astype(np.int32))
    m = GBDTClassifier(3, GBDTConfig(n_rounds=15, max_depth=4)).fit(X, y)
    np.testing.assert_array_equal(np.asarray(m.predict(X)), m.predict_np(X))
    np.testing.assert_allclose(np.asarray(m.predict_proba(X)),
                               m.predict_proba_np(X), rtol=1e-4, atol=1e-5)


def test_gbdt_bin_scan_matches_np_and_sharded_fallback(rng):
    """The bin-quantized gather-free scan is the default inference path:
    it must engage after fit (thresholds land on histogram-bin edges),
    match the exact numpy walk closely, and be *bit-identical* to the
    thread-sharded float-compare fallback (same per-tree accumulation
    order — the property the aligner's stream marker pins)."""
    X = rng.normal(0, 1, (3000, 5)).astype(np.float32)
    y = (2 * X[:, 0] + np.sin(3 * X[:, 1]) - X[:, 3]
         + rng.normal(0, 0.1, 3000))
    m = GBDTRegressor(GBDTConfig(n_rounds=25, max_depth=5)).fit(X, y)
    assert m._binned is not None, "scan path did not engage"
    out = np.asarray(m.predict(X))
    np.testing.assert_allclose(out, m.predict_np(X), rtol=1e-4, atol=1e-4)
    np.testing.assert_array_equal(out, np.asarray(m._predict_sharded(X)))
    # classifier: one multi-class scan program, same guarantees
    yc = (y > np.median(y)).astype(np.int32) + (X[:, 2] > 1)
    c = GBDTClassifier(3, GBDTConfig(n_rounds=10, max_depth=4)).fit(X, yc)
    assert c._binned is not None
    scores = np.asarray(c.predict_scores(X))
    np.testing.assert_array_equal(
        scores, np.asarray(c._predict_scores_sharded(X)))
    np.testing.assert_array_equal(scores.argmax(1), c.predict_np(X))


def test_batched_predict_matches_unbatched(rng):
    from repro.core.feature_engine import batched_rows
    X = rng.normal(0, 1, (1000, 4)).astype(np.float32)
    y = X[:, 0] - 2 * X[:, 3]
    m = GBDTRegressor(GBDTConfig(n_rounds=12, max_depth=3)).fit(X, y)
    np.testing.assert_allclose(batched_rows(m.predict, X, 256),
                               m.predict_np(X), rtol=1e-4, atol=1e-4)
    # ragged tail + batch larger than the input
    np.testing.assert_allclose(batched_rows(m.predict, X[:700], 512),
                               m.predict_np(X[:700]), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(batched_rows(m.predict, X[:10], 512),
                               m.predict_np(X[:10]), rtol=1e-4, atol=1e-4)


def test_batched_rows_full_blocks_are_zero_copy_views(rng):
    """Only the padded tail may copy: every full block must be a view of
    the input (the old driver round-tripped the WHOLE input through one
    np.concatenate whenever the tail needed padding)."""
    from repro.core.feature_engine import batched_rows
    X = rng.normal(size=(1000, 3)).astype(np.float32)
    seen = []

    def fn(blk):
        seen.append(blk)
        return blk[:, 0]

    np.testing.assert_array_equal(batched_rows(fn, X, 256), X[:, 0])
    assert len(seen) == 4
    assert all(np.shares_memory(b, X) for b in seen[:-1])
    assert not np.shares_memory(seen[-1], X)      # padded tail copies
    # exact multiple: no tail, every block a view
    seen.clear()
    np.testing.assert_array_equal(batched_rows(fn, X[:512], 256),
                                  X[:512, 0])
    assert len(seen) == 2
    assert all(np.shares_memory(b, X) for b in seen)


def test_aligner_fit_tiny_n_has_finite_quality():
    """Regression: an empty 20% holdout (n_tr == n) used to poison
    ``col_quality`` with NaN, which sorts first under argsort[::-1] and
    hijacked the primary-column choice."""
    rng = np.random.default_rng(0)
    for n_edges in (1, 2, 4):
        src = rng.integers(0, 3, n_edges).astype(np.int32)
        dst = rng.integers(0, 3, n_edges).astype(np.int32)
        g = Graph(src, dst, 3, 3)
        cont = rng.normal(size=(n_edges, 2)).astype(np.float32)
        cat = rng.integers(0, 2, (n_edges, 1)).astype(np.int32)
        al = GBDTAligner(infer_schema(cont, cat),
                         AlignerConfig(gbdt=GBDTConfig(n_rounds=2))
                         ).fit(g, cont, cat)
        assert np.isfinite(al.col_quality).all(), n_edges
        a_c, a_k = al.align(g, cont, cat, np.random.default_rng(0))
        assert len(a_c) == n_edges and np.isfinite(a_c).all()


def test_random_aligner_truncates_to_graph():
    """Regression: RandomAligner returned every generated row even when
    the graph had fewer edges, desynchronizing the ablation path from
    GBDTAligner.align's ``min(len(rows), n_edges)`` contract."""
    rng = np.random.default_rng(0)
    g, cont, cat = _planted()
    extra_c = np.concatenate([cont, cont[:100]])
    extra_k = np.concatenate([cat, cat[:100]])
    schema = infer_schema(cont, cat)
    r_c, r_k = RandomAligner(schema).align(g, extra_c, extra_k, rng)
    assert len(r_c) == len(r_k) == g.n_edges
    al = GBDTAligner(schema, AlignerConfig(gbdt=GBDTConfig(n_rounds=2))
                     ).fit(g, cont, cat)
    a_c, _ = al.align(g, extra_c, extra_k, np.random.default_rng(1))
    assert len(a_c) == len(r_c)
    # fewer rows than edges: both sides truncate to the row count
    r_c, _ = RandomAligner(schema).align(g, cont[:50], cat[:50], rng)
    assert len(r_c) == 50


def test_align_batched_matches_unbatched():
    g, cont, cat = _planted()
    schema = infer_schema(cont, cat)
    al = GBDTAligner(schema, AlignerConfig(gbdt=FAST), kind="edge").fit(
        g, cont, cat)
    p1 = al.predict(g)
    p2 = al.predict(g, batch=1024)
    np.testing.assert_allclose(p1, p2, rtol=1e-5, atol=1e-5)
    # batched align is still a permutation of the same rows and keeps the
    # planted coupling
    a_c, a_k = al.align(g, cont, cat, np.random.default_rng(3), batch=1024)
    np.testing.assert_allclose(np.sort(a_c[:, 0]), np.sort(cont[:, 0]))
    deg_edge = np.asarray(out_degrees(g))[np.asarray(g.src)]
    corr = np.corrcoef(a_c[:, 0], np.log1p(deg_edge[: len(a_c)]))[0, 1]
    assert corr > 0.8, corr


def test_node_aligner_runs():
    from repro.data.reference import cora_like
    g, cont, cat = cora_like(n=256, n_edges=1024)
    schema = infer_schema(cont, cat)
    al = GBDTAligner(schema, AlignerConfig(gbdt=GBDTConfig(n_rounds=5)),
                     kind="node").fit(g, cont, cat)
    a_c, a_k = al.align(g, cont, cat, np.random.default_rng(0))
    assert a_c.shape[0] == min(g.n_nodes, len(cont))
