"""Structure generator: fitting, sampling, chunking, noise — incl. property
tests (hypothesis) on the paper's invariants."""
import jax
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import rmat
from repro.core.structure import (KroneckerFit, combine, estimate_ratios_mle,
                                  expected_degree_hist, fit_structure,
                                  noisy_thetas)
from repro.graph.ops import Graph, in_degrees, out_degrees


def _sample_fit(fit, seed=0, E=None):
    src, dst = rmat.sample_graph(jax.random.PRNGKey(seed), fit, n_edges=E)
    return np.asarray(src), np.asarray(dst)


def test_mle_recovers_known_theta():
    """Sampling from a known θ then MLE-estimating recovers it closely."""
    fit = KroneckerFit(a=0.5, b=0.2, c=0.2, d=0.1, n=12, m=12, E=200000)
    src, dst = _sample_fit(fit)
    est = estimate_ratios_mle(src, dst, 12, 12)
    np.testing.assert_allclose(est, [0.5, 0.2, 0.2, 0.1], atol=0.01)


def test_mle_rectangular():
    fit = KroneckerFit(a=0.45, b=0.25, c=0.2, d=0.1, n=12, m=9, E=100000)
    src, dst = _sample_fit(fit)
    assert src.max() < 2 ** 12 and dst.max() < 2 ** 9
    est = estimate_ratios_mle(src, dst, 12, 9)
    # square levels only; ratios should still match
    assert abs(est[0] / est[1] - 0.45 / 0.25) < 0.15


def test_expected_degree_hist_matches_empirical():
    """Eq. 7 closed form vs an actual sample."""
    fit = KroneckerFit(a=0.5, b=0.2, c=0.2, d=0.1, n=10, m=10, E=40000)
    src, dst = _sample_fit(fit)
    g = Graph(src, dst, 2 ** 10, 2 ** 10)
    emp = np.bincount(np.asarray(out_degrees(g)), minlength=200)[:200]
    ks = np.arange(200)
    pred = expected_degree_hist(fit.p, fit.n, fit.E, 199, ks)
    # compare in log space over mid-range degrees (tails are noisy)
    sel = (emp > 5) & (ks > 0)
    err = np.abs(np.log1p(pred[sel]) - np.log1p(emp[sel])).mean()
    assert err < 0.5, err


def test_fit_structure_roundtrip():
    """fit → generate → refit gives consistent marginals."""
    true = KroneckerFit(a=0.55, b=0.18, c=0.17, d=0.1, n=11, m=11, E=60000)
    src, dst = _sample_fit(true)
    g = Graph(src, dst, 2 ** 11, 2 ** 11)
    fit = fit_structure(g)
    assert abs(fit.p - true.p) < 0.08, (fit.p, true.p)
    assert abs(fit.q - true.q) < 0.08, (fit.q, true.q)


@pytest.mark.slow
def test_chunked_equals_unchunked_distribution():
    fit = KroneckerFit(a=0.5, b=0.2, c=0.2, d=0.1, n=10, m=10, E=50000)
    s1, d1 = rmat.sample_graph(jax.random.PRNGKey(0), fit)
    s2, d2 = rmat.sample_graph_chunked(jax.random.PRNGKey(0), fit, k_pref=2)
    assert len(s2) == fit.E                      # exact edge count
    # same bit-pair statistics
    e1 = estimate_ratios_mle(np.asarray(s1), np.asarray(d1), 10, 10)
    e2 = estimate_ratios_mle(np.asarray(s2), np.asarray(d2), 10, 10)
    np.testing.assert_allclose(e1, e2, atol=0.02)


def test_chunks_are_id_disjoint():
    fit = KroneckerFit(a=0.5, b=0.2, c=0.2, d=0.1, n=10, m=10, E=20000)
    chunks = rmat.chunk_plan(fit, 2)
    seen = set()
    for ck in chunks:
        assert (ck.src_prefix, ck.dst_prefix) not in seen
        seen.add((ck.src_prefix, ck.dst_prefix))
        s, d = rmat.sample_chunk(jax.random.PRNGKey(ck.index), fit, ck, 2)
        s, d = np.asarray(s), np.asarray(d)
        # all edges carry the chunk's prefix
        assert (s >> (fit.n - 2) == ck.src_prefix).all()
        assert (d >> (fit.m - 2) == ck.dst_prefix).all()
    assert sum(c.n_edges for c in chunks) == fit.E


def test_noise_preserves_simplex():
    fit = KroneckerFit(a=0.5, b=0.2, c=0.2, d=0.1, n=8, m=8, E=1000,
                       noise=0.05)
    th = noisy_thetas(fit, np.random.default_rng(0))
    np.testing.assert_allclose(th.sum(1), 1.0, atol=1e-6)
    assert (th > 0).all()
    # noise varies across levels
    assert np.std(th[:, 0]) > 0


def test_scaling_math():
    fit = KroneckerFit(a=0.5, b=0.2, c=0.2, d=0.1, n=10, m=9, E=1000)
    s2 = fit.scaled(2)                 # density preserving: E×4
    assert (s2.n, s2.m, s2.E) == (11, 10, 4000)
    s2l = fit.scaled(2, density_preserving=False)
    assert s2l.E == 2000


@given(a=st.floats(0.3, 0.7), rb=st.floats(0.5, 5.0), q=st.floats(0.3, 0.9))
@settings(max_examples=50, deadline=None)
def test_combine_is_valid_simplex(a, rb, q):
    """Property: combine() always returns a valid probability 4-simplex;
    p = a+b (Eq. 4) is preserved whenever no simplex projection fires."""
    p = a
    th = combine(p, q, rb)
    assert all(x > 0 for x in th)
    assert abs(sum(th) - 1.0) < 1e-6
    if p + q < 0.95:                        # away from the projection region
        assert abs((th[0] + th[1]) - p) < 1e-6      # p = a + b


@given(seed=st.integers(0, 2 ** 16), n=st.integers(3, 8), m=st.integers(3, 8))
@settings(max_examples=20, deadline=None)
def test_sample_bounds_property(seed, n, m):
    """Property: sampled ids are always within the 2^n × 2^m grid."""
    fit = KroneckerFit(a=0.4, b=0.25, c=0.2, d=0.15, n=n, m=m, E=512)
    src, dst = _sample_fit(fit, seed)
    assert src.min() >= 0 and src.max() < 2 ** n
    assert dst.min() >= 0 and dst.max() < 2 ** m


def test_marginal_p_q_statistics():
    """p = P(src top-bit == 0), q = P(dst top-bit == 0) (Eq. 4)."""
    fit = KroneckerFit(a=0.5, b=0.25, c=0.15, d=0.1, n=12, m=12, E=100000)
    src, dst = _sample_fit(fit)
    top_src0 = 1 - (src >> 11).mean()
    top_dst0 = 1 - (dst >> 11).mean()
    assert abs(top_src0 - fit.p) < 0.01
    assert abs(top_dst0 - fit.q) < 0.01
